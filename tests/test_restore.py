"""The planned read path (PR 8): restore through the full planner.

Contracts under test:

* **Byte identity** — a restore routed through ``compile_plan``
  (``direction="read"``) + ``host_exec.execute_read`` returns exactly
  the bytes the legacy single-reader broadcast reassembly returns, for
  every placement x codec x depth x node-cache setting (the
  ISSUE acceptance cross).
* **Node-level read cache** — per (window, node) the slow hop is paid
  ONCE whatever the co-located reader count (the flat-replica-curve
  property), cache-on never models slower than cache-off, and the two
  modes account the same delivery count.
* **Partial restore** — ``subset=`` reads only the selected leaves'
  byte ranges (``IOTimings.read_bytes`` < 50% of the file for a
  half-tree subset) and passes the other leaves through from
  ``like_tree`` untouched.
* **Read sessions** — repeated restores of one manifest go
  compiled -> trial -> hit, the measured steady state is never worse
  than the first restore, and the manifest fingerprint keys entries
  (a different checkpoint never reuses a stale plan).
* **Torn segments** — a ``.partial`` marker on a needed segment
  refuses the restore (TornWriteError), ranged or planned.
"""
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (CheckpointManager,
                                         manifest_fingerprint,
                                         restore_checkpoint,
                                         save_checkpoint)
from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.faults import partial_marker
from repro.core.session import IOSession


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    dense = rng.integers(0, 256, (40, 64), np.uint8).view(np.float32)
    return {"w": np.asarray(dense, np.float32),
            "b": rng.standard_normal(33).astype(np.float32),
            "opt": {"m": np.zeros((40, 16), np.float32),
                    "v": rng.standard_normal((40, 16)).astype(np.float32)}}


def _like(tree):
    return jax.tree.map(lambda a: np.zeros_like(a), tree)


def _io(session=None, n_ranks=8, n_nodes=2):
    return HostCollectiveIO(n_ranks=n_ranks, n_nodes=n_nodes,
                            stripe_size=1024, stripe_count=4,
                            session=session)


def _save(tmp_path, tree, io):
    man, _ = save_checkpoint(tree, tmp_path / "ck", io=io,
                             method="twophase")
    return man


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------
# byte identity: planned == broadcast across the knob cross
# ---------------------------------------------------------------------
@pytest.mark.parametrize("placement", [None, "spread", "auto"])
@pytest.mark.parametrize("codec", [None, "rle"])
@pytest.mark.parametrize("depth", [None, 1, 2])
@pytest.mark.parametrize("node_cache", [True, False])
def test_planned_restore_byte_identical_to_broadcast(
        tmp_path, placement, codec, depth, node_cache):
    tree = _tree()
    io = _io()
    _save(tmp_path, tree, io)
    like = _like(tree)
    oracle, step0 = restore_checkpoint(tmp_path / "ck", like,
                                       planned=False)
    got, step = restore_checkpoint(
        tmp_path / "ck", like, io=io, cb_bytes=1024,
        pipeline_depth=depth, slow_hop_codec=codec, placement=placement,
        node_cache=node_cache)
    assert step == step0
    _assert_tree_equal(oracle, got)
    _assert_tree_equal(tree, got)


def test_planned_restore_defaults_and_timings(tmp_path):
    tree = _tree()
    io = _io()
    man = _save(tmp_path, tree, io)
    got, _, t = restore_checkpoint(tmp_path / "ck", _like(tree), io=io,
                                   with_timings=True)
    _assert_tree_equal(tree, got)
    assert t.direction == "read" and t.node_cache is True
    # every leaf byte hit disk exactly once (no window re-reads)
    payload = sum(e["nbytes"] for e in man["leaves"])
    assert t.read_bytes == payload
    # 8 ranks on 2 nodes share windows: the cache must have served
    # some co-located readers
    assert t.cache_hits > 0
    assert 0.0 < t.cache_hit_ratio < 1.0
    assert t.total > 0.0


def test_legacy_path_returns_none_timings(tmp_path):
    tree = _tree()
    _save(tmp_path, tree, _io())
    got, _, t = restore_checkpoint(tmp_path / "ck", _like(tree),
                                   planned=False, with_timings=True)
    _assert_tree_equal(tree, got)
    assert t is None


# ---------------------------------------------------------------------
# the node cache: slow hop paid once per (window, node)
# ---------------------------------------------------------------------
def test_slow_hop_bytes_flat_in_colocated_reader_count(tmp_path):
    """The acceptance property: per-node slow-hop bytes are charged
    once per window regardless of how many co-located ranks read it —
    doubling the ranks per node must not move the cache-on slow bytes,
    while cache-off doubles with them."""
    tree = _tree()
    _save(tmp_path, tree, _io())
    man = json.loads((tmp_path / "ck.manifest.json").read_text())
    offs = np.asarray([e["offset"] for e in man["leaves"]], np.int64)
    lens = np.asarray([e["nbytes"] for e in man["leaves"]], np.int64)
    slow_on, slow_off = {}, {}
    for n_ranks in (4, 8, 16):
        io = _io(n_ranks=n_ranks)         # 2 nodes, q = n_ranks / 2
        # replicated read: EVERY rank reads the whole tree (the
        # same-node replica scenario of BENCH_restore)
        reqs = [(offs, lens)] * n_ranks
        for nc in (True, False):
            outs, t = io.read(reqs, str(tmp_path / "ck"), cb_bytes=1024,
                              node_cache=nc)
            (slow_on if nc else slow_off)[n_ranks] = t.slow_hop_slow_bytes
            for o in outs[1:]:
                np.testing.assert_array_equal(o, outs[0])
    assert slow_on[4] == slow_on[8] == slow_on[16]
    assert slow_off[8] == 2 * slow_off[4]
    assert slow_off[16] == 4 * slow_off[4]
    assert slow_on[16] < slow_off[16]


def test_cache_delivery_conservation_and_ratio(tmp_path):
    tree = _tree()
    io = _io()
    _save(tmp_path, tree, io)
    man = json.loads((tmp_path / "ck.manifest.json").read_text())
    offs = np.asarray([e["offset"] for e in man["leaves"]], np.int64)
    lens = np.asarray([e["nbytes"] for e in man["leaves"]], np.int64)
    reqs = [(offs, lens)] * io.n_ranks
    _, t_on = io.read(reqs, str(tmp_path / "ck"), cb_bytes=1024,
                      node_cache=True)
    _, t_off = io.read(reqs, str(tmp_path / "ck"), cb_bytes=1024,
                       node_cache=False)
    assert t_on.cache_hits + t_on.cache_misses == t_off.cache_misses
    assert t_off.cache_hits == 0 and t_off.cache_hit_ratio == 0.0
    # 2 nodes, 4 ranks each: 1 miss + 3 hits per (window, node)
    assert t_on.cache_hit_ratio == pytest.approx(0.75)
    assert t_on.total <= t_off.total


# ---------------------------------------------------------------------
# partial restore
# ---------------------------------------------------------------------
@pytest.mark.parametrize("planned", [True, False])
def test_subset_restore_values_and_passthrough(tmp_path, planned):
    tree = _tree()
    io = _io()
    man = _save(tmp_path, tree, io)
    like = _like(tree)
    sub = [e["path"] for e in man["leaves"] if "opt" not in e["path"]]
    got, _ = restore_checkpoint(tmp_path / "ck", like,
                                io=io if planned else None,
                                subset=sub, planned=planned)
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["b"], tree["b"])
    # unselected leaves pass through from like_tree untouched
    assert (np.asarray(got["opt"]["m"]) == 0).all()
    assert (np.asarray(got["opt"]["v"]) == 0).all()


def test_subset_restore_reads_under_half_the_file(tmp_path):
    tree = _tree()
    io = _io()
    man = _save(tmp_path, tree, io)
    sub = [e["path"] for e in man["leaves"] if "opt" not in e["path"]]
    sub_bytes = sum(e["nbytes"] for e in man["leaves"]
                    if e["path"] in set(sub))
    assert sub_bytes < 0.5 * man["file_len"]  # the subset IS small
    _, _, t = restore_checkpoint(tmp_path / "ck", _like(tree), io=io,
                                 subset=sub, with_timings=True)
    assert t.read_bytes == sub_bytes
    assert t.read_bytes < 0.5 * man["file_len"]


def test_subset_predicate_and_unknown_leaf(tmp_path):
    tree = _tree()
    io = _io()
    _save(tmp_path, tree, io)
    got, _ = restore_checkpoint(tmp_path / "ck", _like(tree), io=io,
                                subset=lambda p: "'b'" in p)
    np.testing.assert_array_equal(got["b"], tree["b"])
    assert (np.asarray(got["w"]) == 0).all()
    with pytest.raises(KeyError, match="unknown leaves"):
        restore_checkpoint(tmp_path / "ck", _like(tree), io=io,
                           subset=["nope"])


# ---------------------------------------------------------------------
# read sessions
# ---------------------------------------------------------------------
def test_read_session_steady_state(tmp_path):
    tree = _tree()
    sess = IOSession()
    io = _io(session=sess)
    _save(tmp_path, tree, io)
    like = _like(tree)
    autos = dict(cb_bytes="auto", pipeline_depth="auto",
                 placement="auto", slow_hop_codec="auto")
    totals, sources = [], []
    for _ in range(4):
        got, _, t = restore_checkpoint(tmp_path / "ck", like, io=io,
                                       with_timings=True, **autos)
        _assert_tree_equal(tree, got)
        totals.append(t.total)
        sources.append(t.plan_source)
    assert sources[0] == "compiled"
    assert sources[-1] == "session-hit"
    # the arbiter guarantee, read side: steady state never worse than
    # the first restore's measured total
    assert totals[-1] <= totals[0] + 1e-15
    assert sess.hits >= 2


def test_read_entries_keyed_by_fingerprint_and_cache_flag(tmp_path):
    tree = _tree()
    sess = IOSession()
    io = _io(session=sess)
    man1 = _save(tmp_path, tree, io)
    like = _like(tree)
    restore_checkpoint(tmp_path / "ck", like, io=io)
    misses_one = sess.misses
    # same manifest, same knobs -> same entry
    restore_checkpoint(tmp_path / "ck", like, io=io)
    assert sess.misses == misses_one
    # the cache flag is key material: node_cache=False is a distinct
    # timing regime, never the same entry
    restore_checkpoint(tmp_path / "ck", like, io=io, node_cache=False)
    assert sess.misses == misses_one + 1
    # a different checkpoint content -> different fingerprint -> a
    # fresh entry, not a stale-plan reuse
    tree2 = _tree(seed=1)
    d2 = tmp_path / "other"
    d2.mkdir()
    man2, _ = save_checkpoint(tree2, d2 / "ck", io=io,
                              method="twophase", step=7)
    assert manifest_fingerprint(man1) != manifest_fingerprint(man2)
    got2, _ = restore_checkpoint(d2 / "ck", _like(tree2), io=io)
    _assert_tree_equal(tree2, got2)


def test_manager_restore_subset_and_session(tmp_path):
    tree = _tree()
    sess = IOSession()
    io = _io(session=sess)
    mgr = CheckpointManager(directory=tmp_path / "mgr", io=io,
                            method="twophase", session=sess)
    for s in range(2):
        mgr.save(tree, s)
    got, step, t = mgr.restore(_like(tree), with_timings=True)
    assert step == 1
    _assert_tree_equal(tree, got)
    assert t.direction == "read"
    got, step, t = mgr.restore(_like(tree), with_timings=True)
    assert t.plan_source in ("session-hit", "session-trial")
    sub, _ = mgr.restore(_like(tree),
                         subset=lambda p: "'w'" in p)
    np.testing.assert_array_equal(sub["w"], tree["w"])
    assert (np.asarray(sub["b"]) == 0).all()


# ---------------------------------------------------------------------
# torn segments + ranged read_file
# ---------------------------------------------------------------------
def test_restore_refuses_torn_segment(tmp_path):
    from repro.core.faults import TornWriteError
    tree = _tree()
    io = _io()
    _save(tmp_path, tree, io)
    marker = Path(partial_marker(str(tmp_path / "ck.seg1")))
    marker.write_text("windows_written=0\n")
    with pytest.raises(TornWriteError):
        restore_checkpoint(tmp_path / "ck", _like(tree), io=io)
    with pytest.raises(TornWriteError):
        restore_checkpoint(tmp_path / "ck", _like(tree), planned=False)


def test_ranged_read_file_matches_full(tmp_path):
    tree = _tree()
    io = _io()
    man = _save(tmp_path, tree, io)
    full = io.read_file(str(tmp_path / "ck"), man["file_len"])
    rng = np.random.default_rng(3)
    for _ in range(16):
        off = int(rng.integers(0, man["file_len"]))
        n = int(rng.integers(1, man["file_len"] - off + 1))
        got = io.read_file(str(tmp_path / "ck"), man["file_len"],
                           offset=off, nbytes=n)
        np.testing.assert_array_equal(got, full[off:off + n])
    # clamped past EOF
    got = io.read_file(str(tmp_path / "ck"), man["file_len"],
                       offset=man["file_len"] - 10, nbytes=100)
    np.testing.assert_array_equal(got, full[-10:])
