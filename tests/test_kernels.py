"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (kernels target TPU; CPU validates the kernel bodies)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro.core import coalesce as co
from repro.core.requests import PAD_OFFSET, RequestList, make_requests
from repro.kernels import ops, ref
from repro.kernels import sort as sort_mod


def _random_sorted(rng, n, cap):
    gaps = rng.integers(1, 9, size=n)
    lens = rng.integers(1, 6, size=n).astype(np.int32)
    offs = (np.cumsum(gaps) + np.concatenate([[0], np.cumsum(lens)[:-1]])
            ).astype(np.int32)
    return make_requests(offs, lens, capacity=cap)


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
@pytest.mark.parametrize("batch", [1, 3])
def test_bitonic_sort_sweep(n, batch):
    rng = np.random.default_rng(n * 7 + batch)
    offs = rng.integers(0, 1 << 20, size=(batch, n)).astype(np.int32)
    lens = rng.integers(0, 100, size=(batch, n)).astype(np.int32)
    carry = rng.integers(0, 1 << 20, size=(batch, n)).astype(np.int32)
    so, sl, sc = sort_mod.bitonic_sort(jnp.asarray(offs), jnp.asarray(lens),
                                       jnp.asarray(carry), interpret=True)
    ro, rl, rc = ref.sort_ref(offs, lens, carry)
    assert np.array_equal(np.asarray(so), np.asarray(ro))
    # keys may repeat; verify (key, carry) multisets match
    for b in range(batch):
        got = sorted(zip(np.asarray(so)[b], np.asarray(sl)[b],
                         np.asarray(sc)[b]))
        want = sorted(zip(offs[b], lens[b], carry[b]))
        assert got == want


def test_sort_pad_to_pow2():
    rng = np.random.default_rng(0)
    r = _random_sorted(rng, 37, 100)  # capacity 100 pads to 128
    starts = co.request_starts(r)
    perm = rng.permutation(100)
    shuffled = RequestList(r.offsets[perm], r.lengths[perm], r.count)
    sr, ss = ops.sort_requests_with(shuffled, starts[perm])
    assert np.array_equal(np.asarray(sr.offsets), np.asarray(r.offsets))
    assert np.array_equal(np.asarray(sr.lengths), np.asarray(r.lengths))
    # carries of PAD slots are meaningless (tie-order among equal keys);
    # compare the valid prefix only
    nv = int(r.count)
    assert np.array_equal(np.asarray(ss[:nv]), np.asarray(starts[:nv]))


def test_sort_chunked_path(monkeypatch):
    monkeypatch.setattr(sort_mod, "MAX_BLOCK", 64)
    rng = np.random.default_rng(1)
    r = _random_sorted(rng, 150, 200)
    perm = rng.permutation(200)
    shuffled = RequestList(r.offsets[perm], r.lengths[perm], r.count)
    sr, _ = ops.sort_requests_with(shuffled, co.request_starts(shuffled))
    assert np.array_equal(np.asarray(sr.offsets), np.asarray(r.offsets))


@pytest.mark.parametrize("n", [8, 64, 513])
def test_coalesce_kernel_sweep(n):
    rng = np.random.default_rng(n)
    # contiguous-heavy pattern so coalescing actually fires
    offs = np.arange(n, dtype=np.int32) * 4
    gaps = rng.random(n) < 0.3
    offs = offs + np.cumsum(gaps).astype(np.int32) * 2
    lens = np.full(n, 4, np.int32)
    r = make_requests(offs, lens, capacity=n)
    out = ops.coalesce(r)
    eo, el, ec = ref.coalesce_ref(r.offsets[None], r.lengths[None])
    assert int(out.count) == int(ec[0])
    assert np.array_equal(np.asarray(out.offsets), np.asarray(eo[0, :n]))
    assert np.array_equal(np.asarray(out.lengths), np.asarray(el[0, :n]))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 60), st.integers(1, 10**6))
def test_pack_kernel_property(n, seed):
    rng = np.random.default_rng(seed)
    r = _random_sorted(rng, n, n)
    starts = co.request_starts(r)
    total = int(np.asarray(r.lengths).sum())
    data = jnp.asarray(rng.integers(1, 1000, size=max(total, 1))
                       .astype(np.int32))
    out_len = int(r.offsets[n - 1]) + int(r.lengths[n - 1]) + 5
    got = ops.pack(r, starts, data, 0, out_len=out_len)
    want = ref.pack_ref(r.offsets, r.lengths, starts, data, 0, out_len)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_pack_with_base_window():
    r = make_requests([10, 20], [4, 4], capacity=4)
    data = jnp.arange(1, 9, dtype=jnp.int32)
    out = ops.pack(r, co.request_starts(r), data, 8, out_len=20)
    want = np.zeros(20, np.int32)
    want[2:6] = [1, 2, 3, 4]
    want[12:16] = [5, 6, 7, 8]
    assert np.array_equal(np.asarray(out), want)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_pack_dtypes(dtype):
    r = make_requests([0, 8], [4, 4], capacity=4)
    data = jnp.arange(1, 9).astype(dtype)
    out = ops.pack(r, co.request_starts(r), data, 0, out_len=12)
    assert out.dtype == dtype
    assert float(out[8]) == 5.0
