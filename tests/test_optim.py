"""Optimizers + schedule."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import adafactor, adamw, global_norm, warmup_cosine


def _fit(opt, steps=150, lr=0.05):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.zeros(())}
    target = jnp.array([1.0, 1.0])
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + p["b"] ** 2

    for _ in range(steps):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params, lr)
    return float(loss_fn(params))


def test_adamw_converges():
    assert _fit(adamw(weight_decay=0.0)) < 1e-2


def test_adafactor_converges():
    assert _fit(adafactor(), steps=300, lr=0.1) < 5e-2


def test_adamw_moments_dtype_and_clip():
    opt = adamw(clip_norm=1.0, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    huge = {"w": jnp.full((4, 4), 1e6)}
    new_p, state = opt.update(huge, state, params, 0.1)
    # clipped: step bounded regardless of raw gradient scale
    assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 10.0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.ones((32, 16)), "v": jnp.ones((7,))}
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (32,)
    assert st["f"]["w"]["vc"].shape == (16,)
    assert st["f"]["v"]["v"].shape == (7,)


def test_global_norm():
    assert abs(float(global_norm({"a": jnp.array([3.0]),
                                  "b": jnp.array([4.0])})) - 5.0) < 1e-6


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.11
    assert float(lr(99)) < 0.2
    assert float(lr(5)) < float(lr(10))
