"""Property-based test: TAM collective write == dense reference for
arbitrary non-overlapping request patterns (hypothesis)."""
import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.checkpoint.host_io import HostCollectiveIO


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5),
       st.sampled_from([1, 2, 4]), st.sampled_from([64, 128, 257]))
def test_tam_write_matches_reference(seed, stripes, nodes_pow, stripe_sz):
    rng = np.random.default_rng(seed)
    n_nodes = nodes_pow
    P = n_nodes * int(rng.integers(1, 5))
    # carve a byte space into random non-overlapping extents
    n_ext = int(rng.integers(1, 40))
    lens = rng.integers(1, 64, size=n_ext)
    gaps = rng.integers(0, 32, size=n_ext)
    offs = np.cumsum(gaps) + np.concatenate([[0], np.cumsum(lens)[:-1]])
    owner = rng.integers(0, P, size=n_ext)
    reqs = []
    for p in range(P):
        sel = owner == p
        o = offs[sel].astype(np.int64)
        l = lens[sel].astype(np.int64)
        order = np.argsort(o, kind="stable")
        o, l = o[order], l[order]
        data = rng.integers(1, 255, size=int(l.sum()), dtype=np.uint8)
        reqs.append((o, l, data))

    io = HostCollectiveIO(n_ranks=P, n_nodes=n_nodes,
                          stripe_size=stripe_sz, stripe_count=stripes)
    import tempfile
    d = tempfile.mkdtemp()
    io.write(reqs, f"{d}/t", method="tam",
             local_aggregators=n_nodes * max(1, P // n_nodes // 2))
    io.write(reqs, f"{d}/p", method="twophase")
    ends = [int(o[-1] + l[-1]) for o, l, _ in reqs if o.size]
    file_len = max(ends) if ends else 1
    ref = np.zeros(file_len, np.uint8)
    for o, l, data in reqs:
        starts = np.concatenate([[0], np.cumsum(l)[:-1]])
        for oo, ll, ss in zip(o, l, starts):
            ref[oo:oo + ll] = data[ss:ss + ll]
    got_t = io.read_file(f"{d}/t", file_len)
    got_p = io.read_file(f"{d}/p", file_len)
    assert np.array_equal(got_t, ref)
    assert np.array_equal(got_p, ref)
