"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests run on the 1
real CPU device (the dry-run sets its own 512-device flag in its own
process; SPMD tests spawn subprocesses with 8 devices)."""
import os
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


@pytest.fixture(scope="session")
def spmd_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC)
    return env
