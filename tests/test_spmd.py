"""SPMD integration tests — run in a subprocess with 8 virtual devices
(the main pytest process keeps the real 1-device view; see conftest)."""
import subprocess
import sys

import pytest


@pytest.mark.timeout(1200)
def test_spmd_checks(spmd_env):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.spmd_checks"],
        env=spmd_env, capture_output=True, text=True, timeout=1200)
    print(proc.stdout)
    if proc.returncode != 0:
        print(proc.stderr[-3000:])
    assert proc.returncode == 0, "FAIL lines:\n" + "\n".join(
        ln for ln in proc.stdout.splitlines() if ln.startswith("FAIL"))
