"""Optional-hypothesis shim: when hypothesis is installed the real
``given``/``settings``/``st`` are re-exported; when it is missing the
property tests are skipped individually while the plain unit tests in
the same module keep running (the seed suite failed collection on this
import)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
