"""Optional-hypothesis shim: when hypothesis is installed the real
``given``/``settings``/``st`` are re-exported; when it is missing the
property tests are skipped individually while the plain unit tests in
the same module keep running (the seed suite failed collection on this
import).

The stub's ``given`` both ATTACHES a skip mark and RAISES
``pytest.skip`` at call time. The mark alone is fragile: it lives in
function attributes, so any later decorator that re-wraps the function
without copying them silently drops it and the test body runs with
``None`` strategy arguments — typically "passing" without testing
anything, which is exactly the local/CI discrepancy this shim must
keep visible (CI asserts hypothesis is importable and fails on any
"hypothesis not installed" skip; locally the same tests must say
SKIPPED with that reason, never PASSED).
"""
import functools
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
    # Fixed CI profile (ROADMAP "hypothesis in CI", PR-5 property tier):
    # derandomized so both JAX matrix pins explore the SAME examples
    # (a pin-specific failure is a compat regression, not luck), a
    # bounded example budget so tier-1 stays fast, and print_blob so a
    # failure prints the @reproduce_failure seed to paste locally.
    # HYPOTHESIS_PROFILE overrides (e.g. a nightly fuzz with more
    # examples and randomization).
    settings.register_profile(
        "repro", settings(derandomize=True, max_examples=50,
                          deadline=None, print_blob=True))
    try:
        settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE",
                                             "repro"))
    except Exception:       # unregistered name: the fixed profile,
        settings.load_profile("repro")   # not a suite-wide collect error
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False
    _REASON = "hypothesis not installed"

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*_a, **_k):
        def deco(f):
            @functools.wraps(f)
            def skipper(*args, **kwargs):
                pytest.skip(_REASON)
            # wraps() copies __wrapped__, which would make pytest
            # introspect the ORIGINAL signature and demand fixtures
            # named after the hypothesis arguments — drop it so the
            # stub collects as a plain zero-fixture test
            del skipper.__wrapped__
            return pytest.mark.skip(reason=_REASON)(skipper)
        return deco

    def settings(*_a, **_k):
        return lambda f: f
