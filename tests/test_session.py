"""IOSession: cache hit/replan semantics, measured-feedback
monotonicity, and byte-identity of session-reused plans."""
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (CheckpointManager,
                                         restore_checkpoint)
from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.domains import FileLayout
from repro.core.plan import IOConfig
from repro.core.session import IOSession
from repro.io_patterns import btio_pattern, e3sm_f_pattern, e3sm_g_pattern


def _io(session=None, stripe_count=4, n_nodes=4, P=16):
    return HostCollectiveIO(n_ranks=P, n_nodes=n_nodes, stripe_size=1024,
                            stripe_count=stripe_count, session=session)


AUTOS = dict(method="tam", local_aggregators=8, cb_bytes="auto",
             pipeline_depth="auto", slow_hop_codec="auto",
             placement="auto")


def test_cache_hit_on_identical_layout_and_config(tmp_path):
    io = _io(IOSession())
    reqs = e3sm_g_pattern(io.n_ranks)
    t1 = io.write(reqs, str(tmp_path / "a"), **AUTOS)
    assert t1.plan_source == "compiled"
    assert io.session.misses == 1 and io.session.hits == 0
    ts = [io.write(reqs, str(tmp_path / f"b{i}"), **AUTOS)
          for i in range(3)]
    assert io.session.misses == 1          # one compile, ever
    assert io.session.hits == 3
    assert ts[-1].plan_source == "session-hit"
    # steady state skips the measurement + autotune sweep: planning is
    # far cheaper than the first write's (min over the hits, so one
    # scheduler hiccup inside a perf_counter window can't flake this)
    assert min(t.plan_seconds for t in ts) < t1.plan_seconds


def test_replan_on_layout_change(tmp_path):
    io = _io(IOSession())
    io.write(e3sm_g_pattern(io.n_ranks), str(tmp_path / "a"), **AUTOS)
    # different request set -> different extent/fingerprint -> new key
    io.write(btio_pattern(io.n_ranks, n=32), str(tmp_path / "b"), **AUTOS)
    assert io.session.misses == 2
    # and a config change on the SAME layout is a new key too
    io.write(e3sm_g_pattern(io.n_ranks), str(tmp_path / "c"),
             **{**AUTOS, "slow_hop_codec": None})
    assert io.session.misses == 3


@pytest.mark.parametrize("pattern", [btio_pattern, e3sm_f_pattern])
def test_measured_feedback_monotone_on_gated_workloads(tmp_path, pattern):
    """The acceptance invariant (also gated at benchmark scale in
    check_regression.py): with a session feeding measurements back,
    the steady-state modeled total never exceeds the first write's —
    a replanned trial that measures worse is reverted, the best
    measured plan wins."""
    io = _io(IOSession(), stripe_count=8)
    reqs = pattern(io.n_ranks)
    totals = [io.write(reqs, str(tmp_path / f"w{i}"), **AUTOS).total
              for i in range(4)]
    assert totals[2] <= totals[0] + 1e-15
    assert totals[3] <= totals[0] + 1e-15
    # and the cross-write cost (planning + modeled write) strictly
    # drops once the plan is cached
    assert io.session.hits >= 2


def test_session_reuse_is_byte_identical(tmp_path):
    """A session-reused (and possibly trial-refined) plan writes the
    same bytes as a fresh compile — plans only move WHERE and WHEN
    bytes travel, never what lands in the file."""
    reqs = btio_pattern(16, n=32)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    fresh = _io(None, stripe_count=8)
    fresh.write(reqs, str(tmp_path / "fresh"), **AUTOS)
    ref = fresh.read_file(str(tmp_path / "fresh"), file_len)
    io = _io(IOSession(), stripe_count=8)
    for i in range(3):
        io.write(reqs, str(tmp_path / f"s{i}"), **AUTOS)
        got = io.read_file(str(tmp_path / f"s{i}"), file_len)
        assert np.array_equal(got, ref), i


def test_session_trial_reverts_when_worse(tmp_path):
    """Force a bad trial: seed the session with feedback whose measured
    node-byte matrix favors a different placement, then check the
    arbiter — whichever plan MEASURES better owns the steady state."""
    io = _io(IOSession(), stripe_count=8)
    reqs = e3sm_g_pattern(io.n_ranks)
    kw = dict(method="twophase", cb_bytes=1024, placement="auto")
    t0 = io.write(reqs, str(tmp_path / "a"), **kw)
    t1 = io.write(reqs, str(tmp_path / "b"), **kw)   # trial or hit
    t2 = io.write(reqs, str(tmp_path / "c"), **kw)   # steady state
    assert t2.total <= min(t0.total, t1.total) + 1e-15
    assert t2.plan_source == "session-hit"


def test_iosession_compile_front_end():
    """The SPMD-side cache: identical (layout, cfg) return the SAME
    plan object; anything different recompiles."""
    s = IOSession()
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=4096,
                   pipeline=True, pipeline_depth=2)
    kw = dict(n_aggregators=4, n_nodes=4, n_ranks=16)
    p1 = s.compile(layout, cfg, **kw)
    p2 = s.compile(layout, cfg, **kw)
    assert p1 is p2
    assert s.hits == 1 and s.misses == 1
    p3 = s.compile(layout, cfg, n_aggregators=4, n_nodes=4, n_ranks=32)
    assert p3 is not p1 and s.misses == 2


def test_pipeline_output_feeds_cache_key_deterministically():
    """The pass pipeline's output is a sound cache key: recompiling
    identical (layout, cfg) through the pipeline hits (same plan
    OBJECT), and any knob delta — including the new ``kernel_fusion``
    — is a distinct key that misses. Plans round-trip the knob tuple
    the session arbitrates on (``_knobs_of``) identically across
    recompiles."""
    from repro.core.session import _knobs_of
    s = IOSession()
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size="auto",
                   pipeline=True, pipeline_depth="auto",
                   slow_hop_codec="auto", placement="auto")
    kw = dict(n_aggregators=4, n_nodes=4, n_ranks=16)
    p1 = s.compile(layout, cfg, **kw)
    p2 = s.compile(layout, cfg, **kw)
    assert p1 is p2 and s.hits == 1             # autos resolved once
    assert _knobs_of(p1) == _knobs_of(p2)
    # a fused config is a different key, same schedule knobs
    import dataclasses
    fused_cfg = dataclasses.replace(cfg, kernel_fusion="fused_round")
    p3 = s.compile(layout, fused_cfg, **kw)
    assert p3 is not p1 and s.misses == 2
    assert p3.kernel_fusion == "fused_round"
    assert _knobs_of(p3) == _knobs_of(p1)       # fusion never reroutes
    assert dataclasses.replace(p3, kernel_fusion=None) == p1


def test_executor_switch_invalidates_measured_totals(tmp_path):
    """Regression: an entry whose measured totals came from one
    executor must not arbitrate a measurement from another against
    them. The in-process executors report MODELED time and the mp
    transport reports wall-clock — incomparable scales; before the fix
    the stale incumbent kept the crown on the wrong clock and the
    session could pin a plan that never measured best on the executor
    actually running."""
    from repro.checkpoint.host_io import IOTimings
    from repro.core.session import _arb_key
    s = IOSession()
    io = _io(s)
    reqs = e3sm_g_pattern(io.n_ranks)
    io.write(reqs, str(tmp_path / "a"), method="twophase", cb_bytes=1024)
    (key,) = list(s._entries)
    entry = s.entry(key)
    assert entry.executor is None          # in-process executor identity
    assert entry.totals                    # modeled totals ingested
    plan = entry.plan
    # a wall-clock measurement "from" the mp executor, numerically much
    # larger than the modeled totals it must never be compared with
    fake = IOTimings()
    fake.transport = "mp"
    fake.io = 123.0
    s.observe(key, plan, fake)
    assert entry.executor == "mp"
    assert list(entry.totals.values()) == [pytest.approx(123.0)]
    assert entry.best_knobs == _arb_key(plan, None)
    # switching back drops the mp total symmetrically
    back = IOTimings()
    back.io = 1.0
    s.observe(key, plan, back)
    assert entry.executor is None
    assert list(entry.totals.values()) == [pytest.approx(1.0)]
    assert entry.best_knobs == _arb_key(plan, None)


def test_checkpoint_manager_holds_a_session(tmp_path):
    tree = {"w": np.arange(4096, dtype=np.float32),
            "b": np.ones(1024, np.float32)}
    io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1024,
                          stripe_count=4)
    mgr = CheckpointManager(directory=tmp_path, io=io, cb_bytes="auto",
                            pipeline_depth="auto", placement="auto",
                            session=IOSession())
    for step in (1, 2, 3):
        t = mgr.save(tree, step)
    assert mgr.session.hits >= 1           # repeated saves reuse plans
    assert t.plan_source in ("session-hit", "session-trial")
    got, step = restore_checkpoint(tmp_path / "ckpt_00000003", tree)
    assert step == 3
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["b"], tree["b"])
