"""Per-arch smoke tests (reduced configs) + cache-consistency checks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T
from repro.models.config import reduced

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.full(
            (B, cfg.num_prefix_embeds, cfg.d_model), 0.01, jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.full((B, cfg.enc_seq, cfg.d_model), 0.01,
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_backward(arch):
    cfg = reduced(configs.get(arch))
    params = T.init_params(KEY, cfg, dtype=jnp.float32)
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    logits, _ = jax.jit(lambda p: T.forward(p, cfg, batch))(params)
    exp_s = S + (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits — the
    strongest cache-correctness check (KV cache, SSM state, conv state,
    local windows, cross-attention all participate)."""
    cfg = reduced(configs.get(arch))
    params = T.init_params(KEY, cfg, dtype=jnp.float32)
    batch = make_batch(cfg)
    full_logits, _ = T.forward(params, cfg, batch)
    npfx = cfg.num_prefix_embeds if cfg.frontend == "vision" else 0

    t_pre = S // 2
    pre_batch = dict(batch, tokens=batch["tokens"][:, :t_pre])
    logits_p, state = T.prefill(params, cfg, pre_batch)
    # prefill's last-token logits == forward logits at position t_pre-1
    want = full_logits[:, npfx + t_pre - 1]
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    # pad caches and continue decoding with teacher forcing
    def grow(c):
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, S - t_pre)
        return jnp.pad(c, pad)
    state = state._replace(kv=[None if c is None else
                               (grow(c[0]), grow(c[1])) for c in state.kv])
    dec = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    for t in range(t_pre, min(t_pre + 3, S)):
        logits_d, state = dec(params, state, batch["tokens"][:, t])
        want = full_logits[:, npfx + t]
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_gemma2_softcap_and_window_applied():
    cfg = reduced(configs.get("gemma2_9b"))
    assert cfg.local_global_alternate and cfg.attn_logit_softcap == 50.0
    params = T.init_params(KEY, cfg, dtype=jnp.float32)
    batch = make_batch(cfg)
    logits, _ = T.forward(params, cfg, batch)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_qwen_bias_present():
    cfg = reduced(configs.get("qwen15_32b"))
    params = T.init_params(KEY, cfg, dtype=jnp.float32)
    assert "bq" in params["blocks"]["slots"][0]["attn"]


def test_jamba_structure():
    cfg = configs.get("jamba_15_large")
    assert cfg.block_period == 8
    assert cfg.is_attn_layer(0) and not cfg.is_attn_layer(1)
    assert cfg.is_moe_layer(1) and not cfg.is_moe_layer(0)


def test_param_counts_match_reported_sizes():
    """Config-derived totals sit near the published sizes."""
    approx = {
        "yi_34b": 34e9, "gemma2_9b": 9e9, "qwen15_32b": 32e9,
        "glm4_9b": 9e9, "kimi_k2": 1.04e12, "mamba2_27b": 2.7e9,
        "llava_next_34b": 34e9,
    }
    for arch, want in approx.items():
        got = configs.get(arch).param_count()
        assert 0.6 * want < got < 1.6 * want, (arch, got, want)
    # MoE actives
    assert configs.get("kimi_k2").active_param_count() < 40e9


def test_mamba2_state_decode_long_context_invariance():
    """SSM decode cost/state is O(1) in history length — state shape
    does not depend on the sequence so far."""
    cfg = reduced(configs.get("mamba2_27b"))
    st = T.init_decode_state(cfg, batch_size=2, max_seq=8)
    shapes1 = [x.shape for x in jax.tree.leaves(st.ssm)]
    st2 = T.init_decode_state(cfg, batch_size=2, max_seq=8192)
    shapes2 = [x.shape for x in jax.tree.leaves(st2.ssm)]
    assert shapes1 == shapes2
