import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax
from jax.sharding import NamedSharding, PartitionSpec
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs, plan_for_cell
from repro.launch import shapes as shp
from repro.launch.hlo_analysis import HloCostModel, top_collectives

arch, shape, mesh_kind = sys.argv[1], sys.argv[2], sys.argv[3]
mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
cell = shp.shape(shape)
plan = plan_for_cell(mesh, cell)
fn, arg_shapes, arg_specs, out_specs = input_specs(arch, cell, plan)
def sh(t):
    f, td = jax.tree.flatten(t, is_leaf=lambda x: isinstance(x, PartitionSpec))
    return td.unflatten([NamedSharding(mesh, s) for s in f])
compiled = jax.jit(fn, in_shardings=sh(arg_specs), out_shardings=sh(out_specs)).lower(*arg_shapes).compile()
hcm = HloCostModel(compiled.as_text())
t = hcm.total()
print(f"flops/dev {t.flops:.3e}  bytes/dev {t.bytes:.3e}  coll/dev {sum(t.coll_bytes.values()):.3e}")
print("counts:", t.coll_count)
print("top collectives (kind, shape, group, GiB):")
for (kind, shape_, n), wire in top_collectives(t, 14):
    print(f"  {kind:20s} {shape_:28s} g={n:3d}  {wire/2**30:9.3f} GiB")

print("top result-bytes (op, shape, GiB):")
for (op, shape_), v in sorted(t.bytes_detail.items(), key=lambda kv: -kv[1])[:14]:
    print(f"  {op:16s} {shape_:32s} {v/2**30:10.2f} GiB")

from collections import Counter
cnt = Counter()
tot = {}
for kind, shape_, n, wire in t.coll_detail:
    cnt[(kind, shape_, n)] += 1
    tot[(kind, shape_, n)] = tot.get((kind, shape_, n), 0) + wire
print("counts for top keys:")
for k, w in sorted(tot.items(), key=lambda kv: -kv[1])[:6]:
    print("  ", k, "n_records:", cnt[k], f"{w/2**30:.1f} GiB")
