"""Render EXPERIMENTS.md tables from results/dryrun + results/roofline."""
import json
import sys
from pathlib import Path

ARCH_ORDER = ["yi_34b", "gemma2_9b", "qwen15_32b", "glm4_9b",
              "whisper_tiny", "jamba_15_large", "llama4_maverick",
              "kimi_k2", "mamba2_27b", "llava_next_34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    for f in Path(d).glob("*.json"):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_table(dr):
    lines = ["| arch | shape | mesh | devices | params | HLO GFLOPs/dev (raw) | arg GiB/dev | temp GiB/dev | compile s |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = dr.get((a, s, m))
                if not r:
                    continue
                if r["status"] == "skipped":
                    if m == "single":
                        lines.append(f"| {a} | {s} | both | — | — | SKIPPED (full attention; DESIGN.md §5) | | | |")
                    continue
                n = r["devices"]
                mem = r["memory"]
                lines.append(
                    f"| {a} | {s} | {m} | {n} | {r['params']/1e9:.1f}B "
                    f"| {r['flops']/1e9:.0f} "
                    f"| {mem['argument_bytes']/n/2**30:.2f} "
                    f"| {mem['temp_bytes']/n/2**30:.2f} "
                    f"| {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(rf):
    lines = ["| arch | shape | mesh | compute s | memory s | coll s | dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    worst = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = rf.get((a, s, m))
                if not r or r.get("status") != "ok":
                    continue
                lines.append(
                    f"| {a} | {s} | {m} | {r['t_compute_s']:.2e} "
                    f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
                    f"| {r['dominant']} | {r['useful_ratio']:.2f} "
                    f"| {r['roofline_fraction']:.4f} |")
                worst.append((r["roofline_fraction"], a, s, m,
                              r["dominant"]))
    worst.sort()
    return "\n".join(lines), worst


if __name__ == "__main__":
    dr = load("results/dryrun")
    rf = load("results/roofline")
    print("## Dry-run table\n")
    print(dryrun_table(dr))
    print("\n## Roofline table\n")
    t, worst = roofline_table(rf)
    print(t)
    print("\nworst fractions:", worst[:6])
    coll = [(r["t_collective_s"] / max(r["t_compute_s"] + r["t_memory_s"], 1e-30), k)
            for k, r in rf.items() if r.get("status") == "ok"]
    coll.sort(reverse=True)
    print("most collective-bound:", coll[:6])
