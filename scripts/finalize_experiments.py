"""Inject the final roofline tables + perf summary into EXPERIMENTS.md."""
import json
from pathlib import Path

ARCH_ORDER = ["yi_34b", "gemma2_9b", "qwen15_32b", "glm4_9b",
              "whisper_tiny", "jamba_15_large", "llama4_maverick",
              "kimi_k2", "mamba2_27b", "llava_next_34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    p = Path(d)
    if not p.exists():
        return out
    for f in p.glob("*.json"):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def table(rf, title):
    lines = [f"**{title}**", "",
             "| arch | shape | mesh | compute s | memory s | coll s "
             "| dominant | useful | frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = rf.get((a, s, m))
                if not r or r.get("status") != "ok":
                    continue
                lines.append(
                    f"| {a} | {s} | {m} | {r['t_compute_s']:.2e} "
                    f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
                    f"| {r['dominant']} | {r['useful_ratio']:.2f} "
                    f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def perf_summary(base, opt):
    lines = ["### Optimized vs baseline, all cells", "",
             "| cell | frac base | frac opt | gain | dominant (opt) | what moved |",
             "|---|---|---|---|---|---|"]
    gains = []
    for key, rb in sorted(base.items()):
        ro = opt.get(key)
        if not ro or rb.get("status") != "ok" or ro.get("status") != "ok":
            continue
        fb, fo = rb["roofline_fraction"], ro["roofline_fraction"]
        gain = fo / max(fb, 1e-30)
        gains.append(gain)
        what = []
        if ro["coll_bytes_per_dev"] < 0.7 * rb["coll_bytes_per_dev"]:
            what.append(f"coll /{rb['coll_bytes_per_dev']/max(ro['coll_bytes_per_dev'],1):.1f}")
        if ro["hlo_bytes_per_dev"] < 0.7 * rb["hlo_bytes_per_dev"]:
            what.append(f"mem /{rb['hlo_bytes_per_dev']/max(ro['hlo_bytes_per_dev'],1):.1f}")
        lines.append(f"| {key[0]}/{key[1]}/{key[2]} | {fb:.4f} | {fo:.4f} "
                     f"| {gain:.1f}x | {ro['dominant']} | {', '.join(what) or '—'} |")
    if gains:
        import statistics
        lines.append("")
        lines.append(f"Geo-mean roofline-fraction gain across "
                     f"{len(gains)} cells: "
                     f"**{statistics.geometric_mean(gains):.2f}x**; "
                     f"max {max(gains):.1f}x.")
    return "\n".join(lines)


if __name__ == "__main__":
    base = load("results/roofline_baseline")
    opt = load("results/roofline_opt")
    doc = Path("EXPERIMENTS.md").read_text()
    tables = (table(base, "Paper-faithful baseline sharding "
                    "(activation-TP, rolled decode, f32 flash)")
              + "\n\n" + table(opt, "Beyond-paper optimized "
                               "(Ulysses seq-sharding, chunk-4096 bf16 "
                               "flash, unrolled decode)"))
    doc = doc.replace("<!-- ROOFLINE_TABLES -->", tables)
    doc = doc.replace("<!-- PERF_SUMMARY -->", perf_summary(base, opt))
    Path("EXPERIMENTS.md").write_text(doc)
    print("tables injected:", len(base), "baseline cells,", len(opt),
          "optimized cells")
