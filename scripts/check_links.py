"""Markdown link checker for the repo's doc set.

Verifies every RELATIVE link target in the given markdown files
exists, and that fragment links (``#section`` / ``file.md#section``)
point at a real heading (GitHub slugification: lowercase, spaces to
``-``, punctuation stripped). External links (http/https/mailto) are
skipped — CI must not flake on the network.

Usage:
    python scripts/check_links.py README.md ARCHITECTURE.md ROADMAP.md
    python scripts/check_links.py            # the default doc set
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = ("README.md", "ARCHITECTURE.md", "ROADMAP.md",
           "docs/knobs.md", "PAPER.md")

LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.M)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, spaces -> '-',
    drop everything that isn't a word character or hyphen."""
    h = re.sub(r"[`*_]|\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    h = h.strip().lower().replace(" ", "-")
    return re.sub(r"[^\w\-]", "", h)


def anchors_of(path: Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    for m in HEADING.finditer(path.read_text()):
        s = slugify(m.group(1))
        n = counts.get(s, 0)
        counts[s] = n + 1
        slugs.add(s if n == 0 else f"{s}-{n}")
    return slugs


def check_file(md: Path) -> list[str]:
    errors = []
    for m in LINK.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        dest = (md.parent / base).resolve() if base else md.resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link -> {target} "
                          f"({dest} does not exist)")
            continue
        if frag and dest.suffix == ".md":
            if frag not in anchors_of(dest):
                errors.append(f"{md}: broken anchor -> {target} "
                              f"(no heading slugs to '#{frag}' "
                              f"in {dest.name})")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] if argv else \
        [REPO / f for f in DEFAULT if (REPO / f).exists()]
    errors = []
    checked = 0
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file does not exist")
            continue
        checked += 1
        errors.extend(check_file(md))
    for e in errors:
        print(f"LINK: {e}", file=sys.stderr)
    if not errors:
        print(f"link check OK ({checked} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
