"""Generate docs/knobs.md from the IOConfig dataclass.

The knob reference is INTROSPECTED, never hand-written: field names,
types and defaults come from ``dataclasses.fields(IOConfig)``, the
per-knob prose from the class docstring, and the auto-resolution /
consumer columns from a script-local table that is checked for STRICT
key equality with the field set — adding, removing or renaming an
IOConfig field without updating this script (and regenerating the doc)
fails loudly instead of silently drifting.

Usage:
    PYTHONPATH=src python scripts/gen_knob_docs.py          # rewrite
    PYTHONPATH=src python scripts/gen_knob_docs.py --check  # CI drift gate
"""
from __future__ import annotations

import argparse
import dataclasses
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.core.plan import IOConfig  # noqa: E402

OUT = REPO / "docs" / "knobs.md"

# Which pass resolves "auto" and which layer consumes the knob — the
# two columns introspection cannot see. Keys MUST equal the IOConfig
# field set (enforced below).
KNOB_META = {
    "req_cap": {
        "auto": "— (capacity; no auto form)",
        "consumer": "both executors (per-rank request-list sizing)",
    },
    "data_cap": {
        "auto": "— (capacity; no auto form)",
        "consumer": "both executors (per-rank payload sizing)",
    },
    "coalesce_cap": {
        "auto": "`None` → `lmem * req_cap` at plan build",
        "consumer": "TAM stage 2 (inter-node metadata forward)",
    },
    "cb_buffer_size": {
        "auto": "`cost_model.optimal_cb` / `optimal_cb_and_depth` at "
                "compile; `rounds_override` refinement on session "
                "feedback",
        "consumer": "`RoundScheduler` (round partition), both executors",
    },
    "pipeline": {
        "auto": "— (boolean; on/off only)",
        "consumer": "round engine (`core.rounds`, host round loop)",
    },
    "pipeline_depth": {
        "auto": "`cost_model.optimal_cb_and_depth` at compile; "
                "`optimal_depth` over measured round times on session "
                "feedback",
        "consumer": "round engine (depth-k window ring)",
    },
    "axis_names": {
        "auto": "— (topology naming; no auto form)",
        "consumer": "SPMD executor (`shard_map` mesh axes)",
    },
    "slow_hop_codec": {
        "auto": "`plan.resolve_slow_hop_codec` "
                "(`cost_model.slow_hop_codec_gain`); measured wire "
                "ratio on session feedback",
        "consumer": "both executors (LA → GA slow-hop payload)",
    },
    "placement": {
        "auto": "`placement.resolve_placement` "
                "(`cost_model.placement_cost`); measured node-byte "
                "matrix / slowdowns on session feedback",
        "consumer": "plan slot→domain map, both executors",
    },
    "kernel_fusion": {
        "auto": "— (explicit lowering choice)",
        "consumer": "`passes.lower_kernels` → SPMD fused-round Pallas "
                    "drain (host path ignores it)",
    },
    "transport": {
        "auto": "— (explicit executor choice; validated by "
                "`passes.resolve_transport`)",
        "consumer": "`host_io` dispatch → `checkpoint/mp_exec` "
                    "(real processes: shared-memory fast hop + socket "
                    "slow hop; wall-clock timings)",
    },
}

HEADER = """\
# IOConfig knob reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python scripts/gen_knob_docs.py
     CI fails on drift via: scripts/gen_knob_docs.py --check -->

One `IOConfig` (`repro.core.plan`) is the whole knob surface of the
collective-I/O paths — `save_checkpoint` / `restore_checkpoint` /
`HostCollectiveIO.write/read` / the SPMD executor all take `config=`.
Bare per-knob kwargs without a config are a deprecated shim (one
`DeprecationWarning`, identical plan). Byte units vs element units:
the checkpoint layer speaks BYTES (`cb_bytes`, `cb_buffer_size` in an
`IOConfig` handed to it), the plan layer speaks ELEMENTS; the
checkpoint front-end converts.

Every `"auto"` resolves at compile time against the modeled workload,
and — when the write runs under an `IOSession` — re-resolves against
MEASURED feedback on later writes of the same key (see
`ARCHITECTURE.md`, "The session feedback loop").

| Knob | Type | Default | `"auto"` resolution | Consumed by |
|---|---|---|---|---|
"""


def _field_docs() -> dict[str, str]:
    """Per-field prose parsed from the IOConfig class docstring
    (``name:  text`` entries with indented continuations)."""
    docs: dict[str, str] = {}
    current = None
    for line in (IOConfig.__doc__ or "").splitlines():
        m = re.match(r"^\s{4}(\w+):\s+(.*\S)\s*$", line)
        if m and not line.startswith("     "):
            current = m.group(1)
            docs[current] = m.group(2)
        elif current and line.strip():
            docs[current] += " " + line.strip()
        elif not line.strip():
            current = None
    return docs


def _fmt_type(tp) -> str:
    return str(tp).replace("|", r"\|")


def render() -> str:
    names = [f.name for f in dataclasses.fields(IOConfig)]
    if set(names) != set(KNOB_META):
        missing = set(names) - set(KNOB_META)
        extra = set(KNOB_META) - set(names)
        raise SystemExit(
            f"gen_knob_docs: KNOB_META out of sync with IOConfig — "
            f"missing {sorted(missing)}, stale {sorted(extra)}; update "
            "scripts/gen_knob_docs.py and regenerate docs/knobs.md")
    docs = _field_docs()
    undocumented = [n for n in names if n not in docs]
    if undocumented:
        raise SystemExit(
            f"gen_knob_docs: IOConfig docstring has no entry for "
            f"{undocumented} — document the field(s) in the class "
            "docstring")
    lines = [HEADER]
    for f in dataclasses.fields(IOConfig):
        default = ("— (required)"
                   if f.default is dataclasses.MISSING else
                   f"`{f.default!r}`")
        lines.append(
            f"| `{f.name}` | `{_fmt_type(f.type)}` | {default} | "
            f"{KNOB_META[f.name]['auto']} | "
            f"{KNOB_META[f.name]['consumer']} |\n")
    lines.append("\n## Per-knob notes (from the class docstring)\n\n")
    for f in dataclasses.fields(IOConfig):
        lines.append(f"- **`{f.name}`** — {docs[f.name]}\n")
    lines.append(
        "\n---\n*Generated by `scripts/gen_knob_docs.py` from "
        "`repro.core.plan.IOConfig`.*\n")
    return "".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if docs/knobs.md is stale "
                         "instead of rewriting it")
    args = ap.parse_args()
    want = render()
    if args.check:
        have = OUT.read_text() if OUT.exists() else ""
        if have != want:
            print("docs/knobs.md is stale — regenerate with:\n"
                  "  PYTHONPATH=src python scripts/gen_knob_docs.py",
                  file=sys.stderr)
            return 1
        print(f"docs/knobs.md is up to date "
              f"({len(dataclasses.fields(IOConfig))} knobs)")
        return 0
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(want)
    print(f"wrote {OUT} ({len(dataclasses.fields(IOConfig))} knobs)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
