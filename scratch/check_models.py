import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models.config import reduced
from repro.models import transformer as T

B, S = 2, 16
rng = jax.random.PRNGKey(0)

for arch in configs.ARCHS:
    cfg = reduced(configs.get(arch))
    params = T.init_params(rng, cfg, dtype=jnp.float32)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.ones((B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32) * 0.01
        batch["labels"] = jax.random.randint(rng, (B, S + cfg.num_prefix_embeds - cfg.num_prefix_embeds), 0, cfg.vocab)
    if cfg.enc_dec:
        batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.01
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch)))(params)
    gn = jax.tree.reduce(lambda a, b: a + b,
                         jax.tree.map(lambda g: jnp.sum(jnp.abs(g)), grads))
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(gn)) and float(gn) > 0, arch

    # prefill + decode
    logits_p, state = jax.jit(lambda p, b: T.prefill(p, cfg, b))(params, batch)
    logits_d, state2 = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))(
        params, state, batch["tokens"][:, 0])
    assert logits_d.shape == (B, cfg.vocab), (arch, logits_d.shape)
    assert np.isfinite(np.asarray(logits_d, np.float32)).all(), arch
    print(f"{arch:20s} loss={float(loss):.3f} decode_ok pos={int(state2.pos)}")
print("ALL MODELS OK")
