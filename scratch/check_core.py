import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (IOConfig, contiguous_layout, make_requests,
                        make_tam_write, make_twophase_write)
from repro.core.twophase import write_reference

mesh = jax.make_mesh((2, 2, 2), ("node", "lagg", "lmem"))
P_ranks = 8
REQ_CAP, DATA_CAP = 8, 64
FILE_LEN = 256
layout = contiguous_layout(FILE_LEN, 2)

rng = np.random.default_rng(0)
# build non-overlapping random requests: partition file into P*REQ_CAP slots
all_off, all_len, all_cnt, all_data = [], [], [], []
slots = rng.permutation(FILE_LEN // 8)  # 32 slots of 8 elems
slots_per_rank = len(slots) // P_ranks
for p in range(P_ranks):
    mine = np.sort(slots[p * slots_per_rank:(p + 1) * slots_per_rank])
    offs = (mine * 8).astype(np.int32)
    lens = rng.integers(1, 9, size=len(mine)).astype(np.int32)
    n = len(offs)
    o = np.full(REQ_CAP, 2**31 - 1, np.int32); o[:n] = offs
    l = np.zeros(REQ_CAP, np.int32); l[:n] = lens
    d = np.zeros(DATA_CAP, np.int32)
    total = lens.sum()
    d[:total] = rng.integers(1, 1000, size=total)
    all_off.append(o); all_len.append(l); all_cnt.append(n); all_data.append(d)

offsets = jnp.asarray(np.stack(all_off))
lengths = jnp.asarray(np.stack(all_len))
counts = jnp.asarray(np.array(all_cnt, np.int32))
data = jnp.asarray(np.stack(all_data))

ref = write_reference(layout, offsets, lengths, counts, data)

cfg = IOConfig(req_cap=32, data_cap=DATA_CAP, coalesce_cap=32)
tp = jax.jit(make_twophase_write(mesh, layout, cfg))
file_tp, stats_tp = tp(offsets, lengths, counts, data)
file_tp = np.asarray(file_tp).reshape(-1)
print("two-phase match:", np.array_equal(file_tp, ref), dict(jax.tree.map(np.asarray, stats_tp)))

tam = jax.jit(make_tam_write(mesh, layout, cfg, use_kernels=True))
file_tam, stats_tam = tam(offsets, lengths, counts, data)
file_tam = np.asarray(file_tam).reshape(-1)
print("tam match:", np.array_equal(file_tam, ref), dict(jax.tree.map(np.asarray, stats_tam)))
if not np.array_equal(file_tam, ref):
    bad = np.nonzero(file_tam != ref)[0]
    print("mismatch idx:", bad[:20], file_tam[bad[:10]], ref[bad[:10]])
